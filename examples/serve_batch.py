"""Batched serving example: continuous-batching scheduler + jitted decode.

Run: PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import (BatchScheduler, Request,
                                greedy_generate)

cfg = get_config("qwen3-4b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# ---- path 1: fixed-batch greedy generation (jitted scan) ----------------
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                             cfg.vocab - 1).astype(jnp.int32)
t0 = time.time()
out = greedy_generate(model, params, {"tokens": prompts}, max_new=16)
print(f"greedy_generate: {out.shape} tokens in {time.time()-t0:.2f}s")

# ---- path 2: continuous batching with slot admission ---------------------
sched = BatchScheduler(model, params, n_slots=4, max_len=48)
for rid in range(6):
    p = jax.random.randint(jax.random.PRNGKey(rid + 10), (8,), 0,
                           cfg.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=rid, prompt=p, max_new=10))
t0, done = time.time(), []
while len(done) < 6:
    done += sched.step()
tok = sum(len(r.out) for r in done)
print(f"scheduler: {len(done)} requests / {tok} tokens in "
      f"{time.time()-t0:.2f}s")
for r in done[:2]:
    print(f"  req {r.rid}: {r.out}")
