"""Deploy a digitally-trained model onto the CrossStack inference engine.

Trains a small LM (digital bf16), then replays its linear layers through
the crossbar digital twin at several cell precisions, reporting the loss
penalty of analog deployment plus the deep-net-mode latency estimate —
the paper's reconfigurability story end to end.

Run: PYTHONPATH=src python examples/crossstack_deploy.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import pipeline as pipe
from repro.core.quant import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import ModelConfig, build_model
from repro.train import optimizer as opt
from repro.train import trainer

# 1) train a tiny LM digitally
cfg = ModelConfig(name="deploy-demo", family="dense", n_layers=2,
                  d_model=128, n_heads=2, n_kv=1, head_dim=64, d_ff=256,
                  vocab=512, act="swiglu")
model = build_model(cfg)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=65, global_batch=8))
step_fn = jax.jit(trainer.make_train_step(
    model, opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=60)),
    donate_argnums=(0,))
state = trainer.init_state(model, jax.random.PRNGKey(0))
for step in range(60):
    state, metrics = step_fn(state, data.batch_at(step))
digital_loss = float(metrics["loss"])
print(f"digital training loss after 60 steps: {digital_loss:.4f}")

# 2) deploy: run the MLP weights through the CrossStack engine
batch = data.batch_at(999)


def loss_with_crossbar_mlp(params, engine_cfg):
    """Replace every MLP matmul with the crossbar digital twin."""
    def xb(x, w):
        return eng.linear(x, w.astype(jnp.float32), engine_cfg)

    import repro.models.layers as L
    orig = L.mlp

    def crossbar_mlp(p, x, act):
        h = xb(x, p["wi"])
        if act == "swiglu":
            h = jax.nn.silu(xb(x, p["wg"])) * h
        h = h.astype(x.dtype)
        return xb(h, p["wo"]).astype(x.dtype)

    L.mlp = crossbar_mlp
    try:
        loss, _ = model.loss_fn(params, batch)
    finally:
        L.mlp = orig
    return float(loss)


params_f32 = jax.tree.map(lambda p: p.astype(jnp.float32), state.params)
base_loss = float(model.loss_fn(params_f32, batch)[0])
print(f"\nheld-out digital loss: {base_loss:.4f}")
print(f"{'mode':10s} {'w_bits':>6s} {'adc':>4s} {'loss':>8s} {'penalty':>9s}")
for mode in ("expansion", "deepnet"):
    for wb, ab in ((8, 12), (4, 10), (2, 8)):
        ecfg = eng.EngineConfig(tile_rows=64, tile_cols=64, mode=mode,
                                quant=QuantConfig(w_bits=wb, in_bits=8,
                                                  adc_bits=ab))
        l = loss_with_crossbar_mlp(params_f32, ecfg)
        print(f"{mode:10s} {wb:6d} {ab:4d} {l:8.4f} {l-base_loss:+9.4f}")

# 3) production deployment: program-once weight residency.  The sweep
# above re-programs every weight on every call (engine.linear) — useful
# for precision studies, wrong for serving.  The crossbar backend programs
# the whole params tree onto resident tiles ONCE and serves reads only.
xcfg = dataclasses.replace(
    cfg, backend="crossbar", dtype=jnp.float32,
    xbar=eng.EngineConfig(tile_rows=64, tile_cols=64, mode="deepnet",
                          quant=QuantConfig(w_bits=8, in_bits=8,
                                            adc_bits=12)))
xmodel = build_model(xcfg)
cache = xmodel.init_cache(8, 65)
logits_x, _ = xmodel.prefill(params_f32, {"tokens": batch["tokens"]}, cache)
ex = xmodel.executor
cache_d = model.init_cache(8, 65)
logits_d, _ = model.prefill(
    params_f32, {"tokens": batch["tokens"]}, cache_d)
dev = float(jnp.abs(logits_x - logits_d).max() / jnp.abs(logits_d).max())
print(f"\nresident deployment: {ex.n_resident} weight grids programmed "
      f"once ({ex.n_devices} devices); prefill rel deviation {dev:.4f}")

# 4) latency: deep-net mode hides reads inside writes (paper's 29 %)
rep = pipe.latency_report(cfg.n_layers * 3, 8)  # 3 matmuls per block
print(f"\ndeep-net pipeline estimate over {cfg.n_layers*3} crossbar layers"
      f" (8-bit inputs): {rep['speedup_frac']*100:.1f}% faster than serial")
