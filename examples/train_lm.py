"""End-to-end train driver: a qwen3-family LM on the synthetic pipeline
with checkpoint/resume.  ~20M params by default so a few hundred steps run
on the CPU container; --d-model 768 --layers 12 gives the ~100M variant
(same code path) for real hardware.

Run: PYTHONPATH=src python examples/train_lm.py --steps 120
"""
import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import ft
from repro.models.model import ModelConfig, build_model
from repro.train import optimizer as opt
from repro.train import trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    cfg = ModelConfig(
        name="train-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 64, n_kv=2,
        head_dim=64, d_ff=4 * args.d_model, vocab=args.vocab,
        act="swiglu", qk_norm=True)
    model = build_model(cfg)
    import math
    n_params = sum(
        math.prod(x.shape) for x in jax.tree.leaves(
            jax.eval_shape(model.init,
                           jax.ShapeDtypeStruct((2,), jax.numpy.uint32))))
    print(f"model: {n_params/1e6:.1f}M params")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                                  global_batch=args.batch))
    opt_cfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20,
                              total_steps=args.steps)
    step_fn = jax.jit(trainer.make_train_step(model, opt_cfg),
                      donate_argnums=(0,))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    state, start = ft.restore_or_init(
        mgr, lambda: trainer.init_state(model, jax.random.PRNGKey(0)))
    if start:
        print(f"[resume] from step {start}")

    t0, first_loss, last_loss = time.time(), None, None
    for step in range(start, args.steps):
        state, metrics = step_fn(state, data.batch_at(step))
        loss = float(metrics["loss"])
        first_loss = first_loss if first_loss is not None else loss
        last_loss = loss
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if (step + 1) % 50 == 0:
            mgr.save(step + 1, state)
    mgr.save(args.steps, state, blocking=True)
    print(f"loss: {first_loss:.3f} -> {last_loss:.3f} "
          f"({'improved' if last_loss < first_loss else 'NO IMPROVEMENT'})")
    return first_loss, last_loss


if __name__ == "__main__":
    main()
