"""N-tenant plane banks: three checkpoints, one crossbar, QoS weights.

Deploys qwen3-4b (smoke) THREE times onto one crossbar executor with
3-plane banks (``DeviceConfig(stack_planes=3)``) — one resident
checkpoint per plane slot — and serves all three tenants' request
streams from the same physical stacks at 2:1:1 QoS weights (tenant A
gets twice the slot quota and admission priority).  Mid-run, tenant C's
checkpoint is hot-swapped in place: with all three planes resident the
bank has no free staging slot, so C's lane pauses for the write window
while A's and B's traffic flows uninterrupted.

Run: PYTHONPATH=src python examples/planebank_serve.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.device import DeviceConfig
from repro.core.engine import EngineConfig
from repro.core.quant import QuantConfig
from repro.models.model import build_model
from repro.serve.engine import BatchScheduler, Request
from repro.serve.hotswap import finetune_delta

cfg = dataclasses.replace(
    get_config("qwen3-4b", smoke=True), backend="crossbar",
    xbar=EngineConfig(tile_rows=64, tile_cols=128, mode="deepnet",
                      quant=QuantConfig(w_bits=4, in_bits=10, adc_bits=10),
                      device=DeviceConfig(stack_planes=3)))
model = build_model(cfg)
params_a = model.init(jax.random.PRNGKey(0))
# tenants B/C: distinct checkpoints (on a fleet: checkpoint/manager.py)
params_b = finetune_delta(params_a, scale=0.05, seed=3)
params_c = finetune_delta(params_a, scale=0.08, seed=5)

sched = BatchScheduler(model, params_a, n_slots=2, max_len=48,
                       tenants={"A": (params_a, 2.0),
                                "B": (params_b, 1.0),
                                "C": (params_c, 1.0)})
ex = model.executor
print(f"plane banks: {ex.stack_planes} planes/bank, {ex.n_resident} "
      f"banks, {ex.n_devices_physical} physical devices (1.0x one "
      f"deployment's stacks; three dedicated arrays would burn 3.0x)")
for t, entry in ex.residency().items():
    print(f"  tenant {t}: v{entry['version']} "
          f"fingerprint={entry['fingerprint']}")

for rid in range(9):
    prompt = jax.random.randint(jax.random.PRNGKey(10 + rid), (6,), 0,
                                cfg.vocab - 1).astype(jnp.int32)
    sched.submit(Request(rid=rid, prompt=prompt, max_new=8,
                         model_id="ABC"[rid % 3]))

params_c2 = finetune_delta(params_a, scale=0.11, seed=9)
done, steps, swapped = [], 0, False
while len(done) < 9 and steps < 400:
    if steps == 4 and not swapped:   # new C checkpoint lands mid-serving
        hs = sched.begin_hot_swap(params_c2, chunks_per_step=6, tenant="C")
        swapped = True
        print(f"step {steps}: tenant-C hot-swap begins "
              f"({hs.plan.total_chunks} chunks, mode="
              f"{'in-place' if hs.plan.in_place else 'staged'}; C's lane "
              f"pauses, A/B traffic flows through the window)")
    for r in sched.step():
        done.append(r)
        print(f"step {steps:3d}: req {r.rid} [tenant {r.model_id}] "
              f"finished -> {r.out[:6]}...")
    steps += 1

(rep,) = sched.swap_history
print(f"\ntenant-C swap promoted at step boundary "
      f"[{rep['swap_mode']}]: C now v{ex.version('C')} "
      f"(A untouched at v{ex.version('A')}, B at v{ex.version('B')})")
print(f"swap window: {rep['decode_steps_during_swap']} A/B decode steps "
      f"served during C's programming (wall {rep['wall_swap_s']:.2f}s, "
      f"zero dropped)")
print("\nQoS (weights 2:1:1 -> slot quotas and served-token shares):")
for t, q in sched.qos_report().items():
    print(f"  tenant {t}: weight={q['weight']:g} slots={q['slots']} "
          f"tokens={q['tokens_served']} share={q['token_share'] * 100:.1f}%")
